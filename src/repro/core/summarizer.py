"""Online–offline orchestration (paper §4.2).

  1. *Dynamic data summarization* (online): point insertions/deletions on
     a Bubble-tree; at any time extract the L leaf clustering features.
  2. *Pre-processing* (offline): leaf CFs → data bubbles; assign original
     points to their closest bubble.
  3. *Clustering* (offline): static HDBSCAN over the bubbles using the
     bubble-aware distances (Eqs. 6–7), weighted flat extraction; original
     points inherit their bubble's label.

The offline pass is where the FLOPs are (L×L distance matrix + MST) and
runs through `repro.kernels.ops` when ``use_jax=True`` (Pallas kernels,
interpret-mode on CPU) or through the numpy reference otherwise.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .bubble_tree import BubbleTree
from .bubbles import DataBubbles, bubble_mutual_reachability
from .hdbscan import HDBSCANResult, hdbscan

__all__ = ["OfflineResult", "cluster_bubbles", "assign_points", "BubbleTreeSummarizer"]


@dataclasses.dataclass
class OfflineResult:
    bubbles: DataBubbles
    bubble_labels: np.ndarray  # (L,)
    point_ids: np.ndarray  # (N,) ids in the tree's point store
    point_labels: np.ndarray  # (N,)
    hdbscan: HDBSCANResult


def cluster_bubbles(
    b: DataBubbles,
    min_pts: int,
    min_cluster_size: float | None = None,
    extent_adjusted: bool = False,
    use_jax: bool = False,
    allow_single_cluster: bool = False,
    backend=None,
) -> HDBSCANResult:
    """Static HDBSCAN on data bubbles (offline step 3).

    ``backend`` (a kernels.ops.ClusterBackend, resolved once by long-lived
    callers) wins over the legacy ``use_jax`` flag when provided.
    """
    if backend is not None or use_jax:
        # d_m is translation-invariant; center before the f32 device path
        # (off-origin coordinates cancel in the ||x||²+||y||²−2xy tiles)
        rep = b.rep - (b.n @ b.rep / max(b.n.sum(), 1.0))[None, :]
        if backend is not None:
            W = np.asarray(backend.bubble_mutual_reachability(rep, b.n, b.extent, min_pts))
        else:
            from repro.kernels import ops

            W = np.asarray(ops.bubble_mutual_reachability(rep, b.n, b.extent, min_pts))
    else:
        W, _ = bubble_mutual_reachability(b, min_pts, extent_adjusted=extent_adjusted)
    eff_mcs = float(min_pts if min_cluster_size is None else min_cluster_size)
    return hdbscan(
        b.rep,
        min_pts=min_pts,
        min_cluster_size=eff_mcs,
        weights=b.n,
        precomputed=W,
        allow_single_cluster=allow_single_cluster,
    )


def assign_points(X: np.ndarray, b: DataBubbles, use_jax: bool = False, backend=None) -> np.ndarray:
    """Offline step 2: nearest-bubble assignment for original points."""
    if backend is not None or use_jax:
        mu = b.rep.mean(axis=0)  # argmin is translation-invariant; see above
        if backend is not None:
            return np.asarray(backend.assign(X - mu, b.rep - mu))
        from repro.kernels import ops

        return np.asarray(ops.assign(X - mu, b.rep - mu))
    sq = (
        np.einsum("id,id->i", X, X)[:, None]
        + np.einsum("jd,jd->j", b.rep, b.rep)[None, :]
        - 2.0 * X @ b.rep.T
    )
    return np.argmin(sq, axis=1)


class BubbleTreeSummarizer:
    """User-facing online–offline pipeline around a BubbleTree."""

    def __init__(
        self,
        dim: int,
        min_pts: int = 10,
        compression: float = 0.01,
        M: int = 10,
        use_jax: bool = False,
        backend: str | None = None,
        **tree_kw,
    ):
        self.tree = BubbleTree(dim=dim, M=M, compression=compression, **tree_kw)
        self.min_pts = int(min_pts)
        self.use_jax = bool(use_jax)
        # backend dispatch resolved once at construction (DESIGN.md §5);
        # None keeps the legacy per-call use_jax behaviour
        self.backend = None
        if backend is not None:
            from repro.kernels import ops

            self.backend = ops.get_backend(backend)

    # online ------------------------------------------------------------
    def insert(self, p) -> int:
        return self.tree.insert(p)

    def delete(self, pid: int):
        self.tree.delete(pid)

    def insert_block(self, X) -> list[int]:
        return self.tree.insert_block(X)

    def delete_block(self, pids):
        self.tree.delete_block(pids)

    # offline -----------------------------------------------------------
    def cluster(self, min_cluster_size: float | None = None) -> OfflineResult:
        b = self.tree.to_bubbles()
        res = cluster_bubbles(
            b,
            self.min_pts,
            min_cluster_size=min_cluster_size,
            use_jax=self.use_jax,
            backend=self.backend,
        )
        pids, X = self.tree.alive_points()
        a = assign_points(X, b, use_jax=self.use_jax, backend=self.backend)
        return OfflineResult(
            bubbles=b,
            bubble_labels=res.labels,
            point_ids=pids,
            point_labels=res.labels[a],
            hdbscan=res,
        )
