"""Exact fully-dynamic HDBSCAN — paper §3 (Algorithms 5 & 6).

Maintains, under point insertions and deletions:
  * the point set (growable arrays + free list),
  * per-point kNN tables (indices + distances, k = minPts),
  * core distances (Def. 1),
  * the MST of the mutual-reachability graph.

TPU-oriented reformulation (DESIGN.md §2): the paper uses an SS-tree +
link-cut tree; both are pointer-serial.  We exploit the paper's own
reduction/contraction rules to express every update as *dense linear
algebra + a small explicit-edge MST pass*:

  insert (Eq. 11):  T' = MST( T ∪ E_inserted ∪ E_modified )
      — Kruskal over ~2n + minPts² explicit edges, weights recomputed
        from the *current* core distances (any stale-weight T edge is
        re-weighted for free since we store raw distances separately).

  delete (Eq. 12):  F = T \\ (E_deleted ∪ E_modified);  T' = Borůvka(F)
      — component-constrained vectorized Borůvka over the dense mutual
        reachability weights of the survivors.

RkNN queries (Appendix A) become masked predicates over one distance row:
``RkNN(p) = { q : d(p,q) < cd(q) }``.  Correctness (not complexity) is
identical to the paper's; the feasibility *benchmark* (fig3) reproduces the
paper's finding that per-update cost approaches static recomputation as
the update fraction grows.
"""

from __future__ import annotations

import numpy as np

from .hdbscan import pairwise_sqdist
from .mst import UnionFind, kruskal_edges

__all__ = ["DynamicHDBSCAN"]


class DynamicHDBSCAN:
    """Exact dynamic maintenance of HDBSCAN's MST (paper §3.2)."""

    def __init__(self, min_pts: int, dim: int, capacity: int = 1024):
        self.min_pts = int(min_pts)
        self.dim = int(dim)
        cap = max(capacity, 16)
        self.X = np.zeros((cap, dim), dtype=np.float64)
        self.alive = np.zeros(cap, dtype=bool)
        # kNN tables over *other* alive points (self excluded, so column 0
        # is the nearest neighbour); cd uses min_pts-1 others per the
        # self-inclusive convention of hdbscan.core_distances.
        self.knn_idx = np.full((cap, self.min_pts), -1, dtype=np.int64)
        self.knn_dst = np.full((cap, self.min_pts), np.inf, dtype=np.float64)
        self.cd = np.zeros(cap, dtype=np.float64)
        # MST as explicit arrays of (u, v, raw_distance); mutual-reach
        # weights are derived on demand: w = max(cd[u], cd[v], raw)
        self.mst_u = np.zeros(0, dtype=np.int64)
        self.mst_v = np.zeros(0, dtype=np.int64)
        self.mst_d = np.zeros(0, dtype=np.float64)
        self._free: list[int] = list(range(cap - 1, -1, -1))
        self.n = 0
        # instrumentation for the feasibility benchmark (paper Fig. 3b–d)
        self.stats = {
            "knn_time": 0.0,
            "mst_time": 0.0,
            "rknn_sizes": [],
            "boruvka_components": [],
        }

    # -- helpers ----------------------------------------------------------

    def _grow(self):
        cap = self.X.shape[0]
        new = cap * 2
        self.X = np.concatenate([self.X, np.zeros((cap, self.dim))])
        self.alive = np.concatenate([self.alive, np.zeros(cap, dtype=bool)])
        self.knn_idx = np.concatenate([self.knn_idx, np.full((cap, self.min_pts), -1, dtype=np.int64)])
        self.knn_dst = np.concatenate([self.knn_dst, np.full((cap, self.min_pts), np.inf)])
        self.cd = np.concatenate([self.cd, np.zeros(cap)])
        self._free.extend(range(new - 1, cap - 1, -1))

    def _alive_ids(self) -> np.ndarray:
        return np.nonzero(self.alive)[0]

    def _dists_to(self, p: np.ndarray, ids: np.ndarray) -> np.ndarray:
        diff = self.X[ids] - p[None, :]
        return np.sqrt(np.maximum(np.einsum("nd,nd->n", diff, diff), 0.0))

    def _core_from_knn(self, i: int) -> float:
        """Self-inclusive cd: distance to the (min_pts-1)-th other point."""
        k = self.min_pts - 1
        if k <= 0:
            return 0.0
        row = self.knn_dst[i]
        if not np.isfinite(row[k - 1]):
            return float(row[np.isfinite(row)].max(initial=0.0))
        return float(row[k - 1])

    def _mst_weights(self) -> np.ndarray:
        return np.maximum(self.mst_d, np.maximum(self.cd[self.mst_u], self.cd[self.mst_v]))

    def total_weight(self) -> float:
        return float(self._mst_weights().sum())

    def mst_edges(self):
        return self.mst_u.copy(), self.mst_v.copy(), self._mst_weights()

    # -- insertion (Algorithm 5) ------------------------------------------

    def insert(self, p) -> int:
        import time

        p = np.asarray(p, dtype=np.float64)
        if not self._free:
            self._grow()
        i = self._free.pop()
        ids = self._alive_ids()
        t0 = time.perf_counter()
        d = self._dists_to(p, ids) if ids.size else np.zeros(0)

        # kNN of p (other points only)
        k = self.min_pts
        if ids.size:
            top = np.argsort(d, kind="stable")[: k]
            self.knn_idx[i, : top.size] = ids[top]
            self.knn_dst[i, : top.size] = d[top]
            self.knn_idx[i, top.size:] = -1
            self.knn_dst[i, top.size:] = np.inf
        self.X[i] = p
        self.alive[i] = True
        self.n += 1
        self.cd[i] = self._core_from_knn(i)

        # RkNN(p): alive q with d(p,q) < current kNN horizon of q
        # (q's horizon = its current k-th other distance; p entering within
        # it shifts q's list and may shrink cd(q))
        if ids.size:
            horizon = self.knn_dst[ids, k - 1]
            rknn = ids[d < horizon]
        else:
            rknn = np.zeros(0, dtype=np.int64)
        self.stats["rknn_sizes"].append(int(rknn.size))
        # update each reverse neighbour's kNN table by sorted insertion of p
        for q in rknn:
            dq = float(np.linalg.norm(self.X[q] - p))
            row_d = self.knn_dst[q]
            row_i = self.knn_idx[q]
            pos = int(np.searchsorted(row_d, dq))
            if pos < k:
                row_d[pos + 1:] = row_d[pos:-1]
                row_i[pos + 1:] = row_i[pos:-1]
                row_d[pos] = dq
                row_i[pos] = i
                self.cd[q] = self._core_from_knn(int(q))
        self.stats["knn_time"] += time.perf_counter() - t0

        # --- MST update via reduction rule (Eq. 11) ---
        t1 = time.perf_counter()
        cand_u = [self.mst_u]
        cand_v = [self.mst_v]
        cand_d = [self.mst_d]
        if ids.size:
            cand_u.append(np.full(ids.size, i, dtype=np.int64))  # E_inserted
            cand_v.append(ids)
            cand_d.append(d)
        # E_modified: edges (r, r') for r in RkNN(p), r' in N_k(r)
        for r in rknn:
            nbr = self.knn_idx[r]
            ok = nbr >= 0
            cand_u.append(np.full(int(ok.sum()), r, dtype=np.int64))
            cand_v.append(nbr[ok])
            cand_d.append(self.knn_dst[r][ok])
        u = np.concatenate(cand_u)
        v = np.concatenate(cand_v)
        raw = np.concatenate(cand_d)
        w = np.maximum(raw, np.maximum(self.cd[u], self.cd[v]))
        # compact node ids for the Kruskal pass
        nodes = self._alive_ids()
        remap = np.full(self.X.shape[0], -1, dtype=np.int64)
        remap[nodes] = np.arange(nodes.size)
        mu, mv, mw = kruskal_edges(remap[u], remap[v], w, nodes.size)
        # recover raw distances of chosen edges: they are either w (if the
        # distance dominated) or re-derived from geometry
        self.mst_u = nodes[mu]
        self.mst_v = nodes[mv]
        diff = self.X[self.mst_u] - self.X[self.mst_v]
        self.mst_d = np.sqrt(np.maximum(np.einsum("nd,nd->n", diff, diff), 0.0))
        self.stats["mst_time"] += time.perf_counter() - t1
        return i

    # -- deletion (Algorithm 6) -------------------------------------------

    def delete(self, i: int):
        import time

        if not self.alive[i]:
            raise KeyError(f"point {i} is not alive")
        k = self.min_pts
        t0 = time.perf_counter()
        self.alive[i] = False
        self.n -= 1
        self._free.append(int(i))
        ids = self._alive_ids()
        # RkNN(p): alive q currently listing i in their kNN table
        rknn = ids[(self.knn_idx[ids] == i).any(axis=1)] if ids.size else np.zeros(0, dtype=np.int64)
        self.stats["rknn_sizes"].append(int(rknn.size))
        # recompute their kNN rows densely (batched — one (U, n) tile)
        if rknn.size and ids.size > 1:
            sq = pairwise_sqdist(self.X[rknn], self.X[ids])
            # mask self-distances
            for row, q in enumerate(rknn):
                sq[row, np.searchsorted(ids, q)] = np.inf
            dst = np.sqrt(np.maximum(sq, 0.0))
            order = np.argsort(dst, axis=1, kind="stable")[:, :k]
            self.knn_idx[rknn] = ids[order]
            self.knn_dst[rknn] = np.take_along_axis(dst, order, axis=1)
            short = ids.size - 1 < k  # fewer others than k
            if short:
                for row, q in enumerate(rknn):
                    m = ids.size - 1
                    self.knn_idx[q, m:] = -1
                    self.knn_dst[q, m:] = np.inf
            for q in rknn:
                self.cd[q] = self._core_from_knn(int(q))
        elif rknn.size:
            self.knn_idx[rknn] = -1
            self.knn_dst[rknn] = np.inf
            self.cd[rknn] = 0.0
        self.knn_idx[i] = -1
        self.knn_dst[i] = np.inf
        self.stats["knn_time"] += time.perf_counter() - t0

        # --- contraction rule (Eq. 12) ---
        t1 = time.perf_counter()
        drop = (self.mst_u == i) | (self.mst_v == i)
        drop |= np.isin(self.mst_u, rknn) | np.isin(self.mst_v, rknn)
        keep_u = self.mst_u[~drop]
        keep_v = self.mst_v[~drop]
        if ids.size == 0:
            self.mst_u = np.zeros(0, dtype=np.int64)
            self.mst_v = np.zeros(0, dtype=np.int64)
            self.mst_d = np.zeros(0, dtype=np.float64)
            self.stats["mst_time"] += time.perf_counter() - t1
            return
        # component-constrained reconnection. Every crossing edge of the
        # cut forest has >= 1 endpoint outside the largest component, so the
        # candidate set (S x all) with S = non-largest-component nodes
        # covers all possible T' completions (dual-tree Borůvka's pruning,
        # flattened to one dense (|S|, n) mutual-reachability tile).
        remap = np.full(self.X.shape[0], -1, dtype=np.int64)
        remap[ids] = np.arange(ids.size)
        uf = UnionFind(ids.size)
        for a, b in zip(remap[keep_u], remap[keep_v]):
            uf.union(int(a), int(b))
        self.stats["boruvka_components"].append(int(uf.n_components))
        if uf.n_components > 1:
            labels = uf.labels()
            uniq, counts = np.unique(labels, return_counts=True)
            biggest = uniq[np.argmax(counts)]
            S = np.nonzero(labels != biggest)[0]  # compact ids
            sq = pairwise_sqdist(self.X[ids[S]], self.X[ids])
            d = np.sqrt(np.maximum(sq, 0.0))
            w = np.maximum(
                d, np.maximum(self.cd[ids[S]][:, None], self.cd[ids][None, :])
            )
            w[np.arange(S.size), S] = np.inf  # self-edges
            eu = np.repeat(S, ids.size)
            ev = np.tile(np.arange(ids.size), S.size)
            ew = w.reshape(-1)
            fin = np.isfinite(ew)
            au, av, aw = kruskal_edges(eu[fin], ev[fin], ew[fin], ids.size, uf=uf)
            self.mst_u = np.concatenate([keep_u, ids[au]])
            self.mst_v = np.concatenate([keep_v, ids[av]])
        else:
            self.mst_u = keep_u
            self.mst_v = keep_v
        diff = self.X[self.mst_u] - self.X[self.mst_v]
        self.mst_d = np.sqrt(np.maximum(np.einsum("nd,nd->n", diff, diff), 0.0))
        self.stats["mst_time"] += time.perf_counter() - t1

    # -- bulk ops ----------------------------------------------------------

    def insert_batch(self, X) -> list[int]:
        return [self.insert(p) for p in np.asarray(X, dtype=np.float64)]

    def delete_batch(self, ids):
        for i in ids:
            self.delete(int(i))
