"""Device-resident exact dynamic HDBSCAN — the jit reformulation of
``core.dynamic`` (paper §3, Algorithms 5 & 6).

``DynamicHDBSCAN`` is host-side numpy with Python loops over RkNN sets;
exact, but every update syncs with the host and is unusable as a serving
hot path.  This module re-expresses both update rules as fixed-shape
array programs over padded power-of-two *capacity buckets* (the same
bucketing discipline as ``core.hierarchy_jax``), and — because the
streaming engine only ever applies same-kind blocks — batches each rule
so a whole block is ONE jit call with ONE MST pass, not a per-op scan:

  insert block (Eq. 11, batched):  T' = MSF( T ∪ (P ∪ M)×V )
      — pure insertions only *decrease* mutual-reachability weights
        (core distances shrink), so any edge absent from the old tree
        whose weight did not change stays redundant (it was the max of
        its tree cycle and still is).  The exact candidate set is the
        old tree plus ALL edges incident to the new points P and to the
        RkNN-affected rows M whose core distances changed — dense
        (|P|+|M|, Np) strips, passed to ``mst.boruvka_strip_jax`` whose
        per-round strip minima are vectorized reductions rather than
        scatters.

  delete block (Eq. 12, batched):  F = T \\ edges(touched);
                                   T' = F ∪ contract(F)-MSF
      — pure deletions only *raise* core distances, so every survivor
        edge (endpoints untouched) is still the minimum crossing edge
        of its tree cut and is kept outright.  The completion is the
        paper's contraction proper: survivor components collapse to
        ≤ s_cap+1 supernodes (every non-largest component lives inside
        S' = V \\ largest), the supernode graph is built with ONE
        scatter over the (|S'|, Np) strip, and a tiny dense Borůvka
        finishes.

  kNN/core-distance maintenance: per-point tables (minPts others, self
  excluded) live in (Np, K) buckets; affected rows (new-point horizon
  hits on insert, rows listing a retired slot on delete) are recomputed
  exactly from gathered distance strips.  RkNN sets are O(minPts²) in
  practice (paper App. A); the ``rk_cap``/``s_cap`` buckets make that
  bound *structural*: a rare oversized set flips the state's ``ok`` bit
  instead of overflowing, and the owner falls back to a from-scratch
  rebuild — exactly the regime where incremental maintenance loses
  anyway (paper Fig. 3).

All distance arithmetic is diff-form f32 (``_dense_dists``), never the
matmul expansion — every stored raw length is bitwise reproducible from
the current coordinates, which is what lets differential tests feed the
host oracle the device's own geometry.  The exactness contract (tested
in ``tests/test_dynamic_jax.py`` / ``test_hybrid_fuzz.py``) is MST
total weight vs the f64 host oracle to 1e-6 relative and flat labels
equal up to permutation.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .mst import boruvka_edges_jax, boruvka_jax, boruvka_strip_jax

__all__ = [
    "DynState",
    "DynamicJaxHDBSCAN",
    "state_mst_weights",
    "state_mutual_reach_dense",
]


class DynState(NamedTuple):
    """Padded dynamic-maintenance state over Np capacity slots.

    Slot ids are stable handles (the free list lives on the host
    wrapper); ``alive`` masks the live ones.  The MST is held as raw
    Euclidean lengths — mutual-reachability weights are derived on
    demand as max(raw, cd[u], cd[v]), so core-distance drift never
    stales stored weights (same trick as the host oracle).
    """

    X: jax.Array  # (Np, d) f32 coordinates (dead slots: stale/zero)
    alive: jax.Array  # (Np,) bool
    knn_idx: jax.Array  # (Np, K) int32 — K = minPts nearest OTHER points
    knn_dst: jax.Array  # (Np, K) f32 ascending (+inf empty)
    cd: jax.Array  # (Np,) f32 core distances (Def. 1, self-inclusive)
    mst_u: jax.Array  # (Np,) int32 slot ids
    mst_v: jax.Array  # (Np,) int32
    mst_raw: jax.Array  # (Np,) f32 raw Euclidean edge lengths
    mst_valid: jax.Array  # (Np,) bool — exactly n_alive-1 True slots
    n_alive: jax.Array  # () int32
    ok: jax.Array  # () bool — False: an update overflowed rk_cap/s_cap
    #   and the state is garbage; the owner must rebuild from scratch


def init_state(capacity: int, dim: int, min_pts: int) -> DynState:
    Np = int(capacity)
    K = int(min_pts)
    return DynState(
        X=jnp.zeros((Np, dim), jnp.float32),
        alive=jnp.zeros((Np,), bool),
        knn_idx=jnp.full((Np, K), -1, jnp.int32),
        knn_dst=jnp.full((Np, K), jnp.inf, jnp.float32),
        cd=jnp.zeros((Np,), jnp.float32),
        mst_u=jnp.zeros((Np,), jnp.int32),
        mst_v=jnp.zeros((Np,), jnp.int32),
        mst_raw=jnp.zeros((Np,), jnp.float32),
        mst_valid=jnp.zeros((Np,), bool),
        n_alive=jnp.asarray(0, jnp.int32),
        ok=jnp.asarray(True, bool),
    )


def _cd_from_rows(knn_dst: jax.Array, min_pts: int) -> jax.Array:
    """Self-inclusive cd per row: the (minPts−1)-th other distance, or
    the largest finite entry when fewer others exist (oracle fallback)."""
    k = min_pts - 1
    if k <= 0:
        return jnp.zeros((knn_dst.shape[0],), jnp.float32)
    kth = knn_dst[:, k - 1]
    finite = jnp.isfinite(knn_dst)
    fallback = jnp.max(jnp.where(finite, knn_dst, 0.0), axis=1)
    return jnp.where(jnp.isfinite(kth), kth, fallback)


def _dense_dists(X: jax.Array) -> jax.Array:
    """(Np, Np) pairwise distances in diff-form f32 — the SAME arithmetic
    every strip uses (sqrt of the summed squared difference, never the
    ‖x‖²+‖y‖²−2xy expansion), so weights produced by a rebuild are
    bitwise identical to what an incremental step would derive for the
    same pair.  Row-blocked through lax.map to bound the (B, Np, d)
    broadcast at large capacities."""
    Np, d = X.shape
    B = min(Np, 64)
    pad = (-Np) % B

    def row_block(xb):
        return jnp.sqrt(jnp.sum((xb[:, None, :] - X[None, :, :]) ** 2, axis=-1))

    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    out = jax.lax.map(row_block, Xp.reshape((Np + pad) // B, B, d))
    return out.reshape(Np + pad, Np)[:Np]


def _strip_dists(rows: jax.Array, X: jax.Array) -> jax.Array:
    """(U, Np) diff-form distances from gathered rows to every slot."""
    return jnp.sqrt(jnp.sum((rows[:, None, :] - X[None, :, :]) ** 2, axis=-1))


def _scatter_rows(A: jax.Array, tgt: jax.Array, rows_new: jax.Array) -> jax.Array:
    """Write rows_new at row indices tgt; indices == len(A) are trash."""
    pad = jnp.zeros((1,) + A.shape[1:], A.dtype)
    return jnp.concatenate([A, pad]).at[tgt].set(rows_new)[: A.shape[0]]


# --------------------------------------------------------------------------
# batched insertion (Algorithm 5 / Eq. 11)
# --------------------------------------------------------------------------

# trace-contract: dyn_insert_batch rules=f32,no-callbacks,pow2
@functools.partial(jax.jit, static_argnames=("min_pts", "rk_cap"))
def insert_batch(state: DynState, P, slots, valid, *, min_pts: int,
                 rk_cap: int) -> DynState:
    """Apply a padded block of insertions as ONE fused update.

    P: (Bp, d) f32; slots: (Bp,) int32 pre-assigned free slots (host
    free list); valid: (Bp,) bool — padding rows are exact no-ops.
    Exactness: pure insertions only shrink core distances, so
    MST(final) ⊆ T ∪ (P ∪ M)×V with M ⊇ every row whose kNN table (and
    hence cd) changed — the horizon criterion below.
    """
    P = P.astype(jnp.float32)
    slots = slots.astype(jnp.int32)
    Np, K = state.knn_idx.shape
    Bp = P.shape[0]
    iota = jnp.arange(Np, dtype=jnp.int32)
    tgt = jnp.where(valid, slots, Np)  # trash-slot scatter for pad rows

    alive_old = state.alive
    X2 = _scatter_rows(state.X, tgt, P)
    alive2 = jnp.concatenate([alive_old, jnp.zeros((1,), bool)]).at[tgt].set(True)[:Np]

    # new rows' distances vs the FINAL population (new points see each other)
    D_new = _strip_dists(P, X2)  # (Bp, Np)
    m_new = valid[:, None] & alive2[None, :] & (iota[None, :] != slots[:, None])
    D_new_m = jnp.where(m_new, D_new, jnp.inf)
    neg_d, idx = jax.lax.top_k(-D_new_m, K)
    nd = -neg_d
    ni = jnp.where(jnp.isfinite(nd), idx.astype(jnp.int32), -1)
    knn_dst = _scatter_rows(state.knn_dst, tgt, nd)
    knn_idx = _scatter_rows(state.knn_idx, tgt, ni)
    cd = _scatter_rows(state.cd[:, None], tgt, _cd_from_rows(nd, min_pts)[:, None])[:, 0]

    # M: old rows with any new point inside their kNN horizon (strict <,
    # matching the oracle); their tables+cds are recomputed exactly
    horizon = state.knn_dst[:, K - 1]
    dmin = jnp.min(jnp.where(valid[:, None], D_new, jnp.inf), axis=0)
    M = alive_old & (dmin < horizon)
    rk_n = jnp.sum(M, dtype=jnp.int32)
    ok = state.ok & (rk_n <= rk_cap)
    (rids,) = jnp.nonzero(M, size=rk_cap, fill_value=0)
    rids = rids.astype(jnp.int32)
    rvalid = jnp.arange(rk_cap) < rk_n
    D_M = _strip_dists(X2[rids], X2)  # (rk_cap, Np)
    m_M = rvalid[:, None] & alive2[None, :] & (iota[None, :] != rids[:, None])
    D_M_m = jnp.where(m_M, D_M, jnp.inf)
    neg_d, idx = jax.lax.top_k(-D_M_m, K)
    md = -neg_d
    mi = jnp.where(jnp.isfinite(md), idx.astype(jnp.int32), -1)
    rtgt = jnp.where(rvalid, rids, Np)
    knn_dst = _scatter_rows(knn_dst, rtgt, md)
    knn_idx = _scatter_rows(knn_idx, rtgt, mi)
    cd = _scatter_rows(cd[:, None], rtgt, _cd_from_rows(md, min_pts)[:, None])[:, 0]

    # --- Eq. 11 (batched): MSF over T ∪ (P ∪ M)×V ---
    ew_tree = jnp.maximum(
        state.mst_raw, jnp.maximum(cd[state.mst_u], cd[state.mst_v])
    )
    ew_tree = jnp.where(state.mst_valid, ew_tree, jnp.inf)
    sids = jnp.concatenate([jnp.minimum(slots, Np - 1), rids])
    D_strip = jnp.concatenate([D_new, D_M], axis=0)
    smask = jnp.concatenate([m_new, m_M], axis=0)
    SW = jnp.maximum(D_strip, jnp.maximum(cd[sids][:, None], cd[None, :]))
    SW = jnp.where(smask, SW, jnp.inf)
    pay, pay_ok, _ = boruvka_strip_jax(
        state.mst_u, state.mst_v, ew_tree, state.mst_valid, sids, SW, smask, Np
    )
    E = Np
    is_strip = pay >= E
    t_idx = jnp.minimum(pay, E - 1)
    s_flat = jnp.maximum(pay - E, 0)
    mu = jnp.where(is_strip, sids[s_flat // Np], state.mst_u[t_idx])
    mv = jnp.where(is_strip, (s_flat % Np).astype(jnp.int32), state.mst_v[t_idx])
    s_flat = jnp.minimum(s_flat, (Bp + rk_cap) * Np - 1)
    mraw = jnp.where(
        is_strip, D_strip.reshape(-1)[s_flat], state.mst_raw[t_idx]
    )
    return state._replace(
        X=X2,
        alive=alive2,
        knn_idx=knn_idx,
        knn_dst=knn_dst,
        cd=cd,
        mst_u=jnp.where(pay_ok, mu, 0),
        mst_v=jnp.where(pay_ok, mv, 0),
        mst_raw=jnp.where(pay_ok, mraw, 0.0),
        mst_valid=pay_ok,
        n_alive=state.n_alive + jnp.sum(valid, dtype=jnp.int32),
        ok=ok,
    )


# --------------------------------------------------------------------------
# batched deletion (Algorithm 6 / Eq. 12)
# --------------------------------------------------------------------------

# trace-contract: dyn_delete_batch rules=f32,no-callbacks,pow2
@functools.partial(jax.jit, static_argnames=("min_pts", "rk_cap", "s_cap"))
def delete_batch(state: DynState, slots, valid, *, min_pts: int, rk_cap: int,
                 s_cap: int) -> DynState:
    """Apply a padded block of deletions as ONE fused update.

    Survivor forest kept outright (deletions only raise core
    distances), completion via the contracted component graph.
    """
    slots = slots.astype(jnp.int32)
    Np, K = state.knn_idx.shape
    iota = jnp.arange(Np, dtype=jnp.int32)
    tgt = jnp.where(valid, slots, Np)
    del_flag = jnp.concatenate([jnp.zeros((Np,), bool), jnp.zeros((1,), bool)]).at[
        tgt
    ].set(True)[:Np]
    alive = state.alive & ~del_flag
    n_del = jnp.sum(valid & state.alive[jnp.minimum(slots, Np - 1)], dtype=jnp.int32)

    # RkNN: alive rows listing any retired slot — recompute from a strip
    safe_idx = jnp.minimum(jnp.maximum(state.knn_idx, 0), Np - 1)
    lists = alive & (del_flag[safe_idx] & (state.knn_idx >= 0)).any(axis=1)
    rk_n = jnp.sum(lists, dtype=jnp.int32)
    ok = state.ok & (rk_n <= rk_cap)
    (rids,) = jnp.nonzero(lists, size=rk_cap, fill_value=0)
    rids = rids.astype(jnp.int32)
    rvalid = jnp.arange(rk_cap) < rk_n
    D = _strip_dists(state.X[rids], state.X)
    D = jnp.where(alive[None, :], D, jnp.inf)
    D = jnp.where(iota[None, :] == rids[:, None], jnp.inf, D)
    neg_d, nidx = jax.lax.top_k(-D, K)
    nd = -neg_d
    ni = jnp.where(jnp.isfinite(nd), nidx.astype(jnp.int32), -1)
    rtgt = jnp.where(rvalid, rids, Np)
    knn_dst = _scatter_rows(state.knn_dst, rtgt, nd)
    knn_idx = _scatter_rows(state.knn_idx, rtgt, ni)
    knn_dst = jnp.where(del_flag[:, None], jnp.inf, knn_dst)
    knn_idx = jnp.where(del_flag[:, None], -1, knn_idx)
    cd = jnp.where(lists, _cd_from_rows(knn_dst, min_pts), state.cd)
    cd = jnp.where(del_flag, 0.0, cd)

    # --- Eq. 12 (batched): survivor forest + contracted completion ---
    touched = lists | del_flag
    keep = state.mst_valid & ~(touched[state.mst_u] | touched[state.mst_v])
    _, _, labels_f = boruvka_edges_jax(
        state.mst_u,
        state.mst_v,
        jnp.where(keep, jnp.asarray(0.0, jnp.float32), jnp.asarray(jnp.inf, jnp.float32)),
        keep,
        Np
    )
    # compact component ids over ALIVE nodes (dead singletons excluded)
    rep_alive = jnp.where(alive, labels_f, Np)
    present = jnp.zeros((Np + 1,), jnp.int32).at[rep_alive].set(1)[:Np]
    crank = (jnp.cumsum(present) - 1).astype(jnp.int32)
    Kc = s_cap + 1  # ≤ s_cap non-largest comps + the largest (else ok=False)
    cid = jnp.where(alive, crank[labels_f], Kc)  # dead → dropped on scatter
    cnt = jnp.zeros((Kc + 1,), jnp.int32).at[jnp.minimum(cid, Kc)].add(
        alive.astype(jnp.int32)
    )[:Kc]
    biggest = jnp.argmax(cnt).astype(jnp.int32)
    s_mask = alive & (cid != biggest)
    s_n = jnp.sum(s_mask, dtype=jnp.int32)
    ok = ok & (s_n <= s_cap) & (jnp.sum(present) <= Kc)
    (sids,) = jnp.nonzero(s_mask, size=s_cap, fill_value=0)
    sids = sids.astype(jnp.int32)
    svalid = jnp.arange(s_cap) < s_n
    DS = _strip_dists(state.X[sids], state.X)
    WS = jnp.maximum(DS, jnp.maximum(cd[sids][:, None], cd[None, :]))
    rowc = cid[sids]
    BIG = jnp.asarray(np.iinfo(np.int32).max, jnp.int32)
    # Every crossing pair has ≥ 1 endpoint in S', so the component graph
    # splits into (a) S'-component → largest, reduced DENSELY per strip
    # row (a (s_cap, Np) masked min — vector ops, not a 1M-element
    # scatter), and (b) S'×S', a (s_cap, s_cap) gathered block whose
    # scatter is tiny.  This keeps the big strip out of scatter land —
    # the CPU bottleneck of the whole delete path.
    to_big = svalid[:, None] & alive[None, :] & (cid[None, :] == biggest)
    w_big = jnp.where(to_big, WS, jnp.inf)
    row_min = jnp.min(w_big, axis=1)  # (s_cap,)
    row_arg = jnp.argmin(w_big, axis=1).astype(jnp.int32)
    comp_big_w = jnp.full((Kc + 1,), jnp.inf, WS.dtype).at[jnp.minimum(rowc, Kc)].min(
        jnp.where(svalid, row_min, jnp.inf)
    )[:Kc]
    hit_r = svalid & (row_min == comp_big_w[jnp.minimum(rowc, Kc - 1)])
    comp_big_row = jnp.full((Kc + 1,), BIG).at[jnp.minimum(rowc, Kc)].min(
        jnp.where(hit_r, jnp.arange(s_cap, dtype=jnp.int32), BIG)
    )[:Kc]
    safe_row = jnp.minimum(comp_big_row, s_cap - 1)
    comp_big_flat = safe_row * Np + row_arg[safe_row]
    # (b) the S'×S' block (columns gathered at the S' ids)
    WSS = WS[:, sids]  # (s_cap, s_cap)
    colc = rowc  # column j is strip row j's node
    cross = (
        svalid[:, None] & svalid[None, :] & (rowc[:, None] != colc[None, :])
    )
    pair = jnp.where(cross, rowc[:, None] * Kc + colc[None, :], Kc * Kc)
    pair_f = pair.reshape(-1)
    flat_w = jnp.where(cross, WSS, jnp.inf).reshape(-1)
    Wc = jnp.full((Kc * Kc + 1,), jnp.inf, WS.dtype).at[pair_f].min(flat_w)[:-1]
    hit = cross.reshape(-1) & (flat_w == Wc[jnp.minimum(pair_f, Kc * Kc - 1)])
    # witness indices flattened into the FULL strip: row r, column sids[c]
    full_flat = (
        jnp.arange(s_cap, dtype=jnp.int32)[:, None] * Np + sids[None, :]
    ).reshape(-1)
    Ec = jnp.full((Kc * Kc + 1,), BIG).at[pair_f].min(
        jnp.where(hit, full_flat, BIG)
    )[:-1]
    Wc = Wc.reshape(Kc, Kc)
    Ec = Ec.reshape(Kc, Kc)
    # merge in the to-largest column
    safe_big = jnp.minimum(biggest, Kc - 1)
    better = comp_big_w < Wc[:, safe_big]
    Wc = Wc.at[:, safe_big].set(jnp.where(better, comp_big_w, Wc[:, safe_big]))
    Ec = Ec.at[:, safe_big].set(jnp.where(better, comp_big_flat, Ec[:, safe_big]))
    # symmetrize (S'×S' pairs appear in both orientations, S'×largest in one)
    pick_t = Wc.T < Wc
    tie = Wc.T == Wc
    Wsym = jnp.where(pick_t, Wc.T, Wc)
    Esym = jnp.where(pick_t, Ec.T, jnp.where(tie, jnp.minimum(Ec, Ec.T), Ec))
    ea, eb, _, evalid_c = boruvka_jax(Wsym)
    # witness point pair of each selected component edge
    flat = jnp.minimum(Esym[ea, eb], s_cap * Np - 1)
    cu = sids[flat // Np]
    cv = (flat % Np).astype(jnp.int32)
    craw = DS.reshape(-1)[flat]

    # assemble the new tree: kept survivor edges, then completion edges
    krank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    n_keep = jnp.sum(keep, dtype=jnp.int32)
    tgt_k = jnp.where(keep, krank, Np)
    nu = jnp.zeros((Np + 1,), jnp.int32).at[tgt_k].set(state.mst_u)
    nv = jnp.zeros((Np + 1,), jnp.int32).at[tgt_k].set(state.mst_v)
    nr = jnp.zeros((Np + 1,), jnp.float32).at[tgt_k].set(state.mst_raw)
    nval = jnp.zeros((Np + 1,), bool).at[tgt_k].set(keep)
    crank2 = jnp.cumsum(evalid_c.astype(jnp.int32)) - 1
    tgt_c = jnp.where(evalid_c, n_keep + crank2, Np)
    nu = nu.at[tgt_c].set(cu)
    nv = nv.at[tgt_c].set(cv)
    nr = nr.at[tgt_c].set(craw)
    nval = nval.at[tgt_c].set(evalid_c)
    return state._replace(
        alive=alive,
        knn_idx=knn_idx,
        knn_dst=knn_dst,
        cd=cd,
        mst_u=nu[:Np],
        mst_v=nv[:Np],
        mst_raw=nr[:Np],
        mst_valid=nval[:Np],
        n_alive=state.n_alive - n_del,
        ok=ok,
    )


# trace-contract: dyn_rebuild rules=f32,no-callbacks,pow2
@functools.partial(jax.jit, static_argnames=("min_pts",))
def rebuild(state: DynState, *, min_pts: int) -> DynState:
    """From-scratch device build from X/alive only: dense d → kNN tables
    → core distances → full Borůvka MST.  This is the fallback "full
    pass" of the hybrid path (and the recovery from an overflowed
    incremental state); one call costs what the offline pipeline's
    d_m → Borůvka stages cost, which is exactly the crossover the
    UpdatePolicy steers around.
    """
    Np, K = state.knn_idx.shape
    iota = jnp.arange(Np, dtype=jnp.int32)
    alive = state.alive
    n = jnp.sum(alive, dtype=jnp.int32)
    D = _dense_dists(state.X)
    live2 = alive[:, None] & alive[None, :]
    D = jnp.where(live2 & (iota[:, None] != iota[None, :]), D, jnp.inf)
    neg_d, nidx = jax.lax.top_k(-D, K)
    nd = -neg_d
    ni = jnp.where(jnp.isfinite(nd), nidx.astype(jnp.int32), -1)
    knn_dst = jnp.where(alive[:, None], nd, jnp.inf)
    knn_idx = jnp.where(alive[:, None], ni, -1)
    cd = jnp.where(alive, _cd_from_rows(knn_dst, min_pts), 0.0)
    W = jnp.maximum(D, jnp.maximum(cd[:, None], cd[None, :]))
    W = jnp.where(live2, W, jnp.inf)
    eu, ev, ew, valid = boruvka_jax(W)
    safe_u = jnp.minimum(eu, Np - 1).astype(jnp.int32)
    safe_v = jnp.minimum(ev, Np - 1).astype(jnp.int32)
    return state._replace(
        knn_idx=knn_idx,
        knn_dst=knn_dst,
        cd=cd,
        mst_u=jnp.where(valid, safe_u, 0),
        mst_v=jnp.where(valid, safe_v, 0),
        mst_raw=jnp.where(valid, D[safe_u, safe_v], 0.0),
        mst_valid=valid,
        n_alive=n,
        ok=jnp.asarray(True, bool),
    )


def state_mst_weights(state: DynState) -> jax.Array:
    """(Np,) mutual-reachability weights of the maintained tree (invalid
    slots 0) — derived from raw lengths + current core distances."""
    w = jnp.maximum(
        state.mst_raw, jnp.maximum(state.cd[state.mst_u], state.cd[state.mst_v])
    )
    return jnp.where(state.mst_valid, w, 0.0)


def state_mutual_reach_dense(state: DynState) -> np.ndarray:
    """(n, n) f64 mutual-reachability matrix over the alive slots
    (ascending slot order), reproducing the device's f32 arithmetic
    bit for bit (diff-form distances + max with the maintained core
    distances).  Differential tests feed this to the host oracle so a
    disagreement is a maintenance/hierarchy bug, never f32-vs-f64
    geometry drift on tie-critical edges (same convention as
    tests/test_streaming_fuzz.py)."""
    alive = np.asarray(state.alive)
    ids = np.nonzero(alive)[0]
    X = jnp.asarray(np.asarray(state.X)[ids])
    cd = np.asarray(state.cd)[ids].astype(np.float64)
    D = np.asarray(_dense_dists(X), dtype=np.float64)
    W = np.maximum(D, np.maximum(cd[:, None], cd[None, :]))
    np.fill_diagonal(W, 0.0)
    return W


# --------------------------------------------------------------------------
# host wrapper: free list, capacity buckets, overflow recovery
# --------------------------------------------------------------------------

class DynamicJaxHDBSCAN:
    """Host handle over the device state: slot free list, power-of-two
    capacity growth, and rebuild-on-overflow.  API mirrors the oracle
    (``insert_batch``/``delete_batch`` by slot id); blocks are padded to
    power-of-two buckets so each (capacity, block) pair compiles once.
    """

    MIN_BLOCK = 4

    def __init__(
        self,
        min_pts: int,
        dim: int,
        capacity: int = 256,
        rk_cap: int | None = None,
        s_cap: int | None = None,
    ):
        self.min_pts = int(min_pts)
        self.dim = int(dim)
        # capacity must cover the (Np, K) kNN tables' top_k (K ≤ Np)
        cap = max(16, 2 * self.min_pts, int(capacity))
        cap = 1 << (max(cap - 1, 1)).bit_length()
        # user-pinned caps are used as-is; None scales with the block
        # (RkNN sets are O(minPts²)-ish per op, additive over a block)
        self._rk_cap = int(rk_cap) if rk_cap is not None else None
        self._s_cap = int(s_cap) if s_cap is not None else None
        self.state = init_state(cap, self.dim, self.min_pts)
        self._free: list[int] = list(range(cap - 1, -1, -1))
        self.stats = {"inserts": 0, "deletes": 0, "overflow_rebuilds": 0, "grows": 0}

    # -- host bookkeeping --------------------------------------------------

    @property
    def capacity(self) -> int:
        return int(self.state.X.shape[0])

    @property
    def n(self) -> int:
        return int(self.state.n_alive)

    @property
    def ok(self) -> bool:
        return bool(self.state.ok)

    @property
    def rk_cap(self) -> int:
        return self._rk_cap if self._rk_cap is not None else self._eff_cap(1)

    @property
    def s_cap(self) -> int:
        return self._s_cap if self._s_cap is not None else self._eff_s_cap(1)

    def _eff_cap(self, bp: int) -> int:
        # RkNN sets average ≈ minPts per op (paper App. A) with heavy
        # tails on clustered data (sparse points carry wide horizons), so
        # floor at minPts² and scale with the block; clamp at capacity/4
        # — past that the strip work rivals a rebuild, which the overflow
        # fallback pays anyway.
        want = max(32, self.min_pts * self.min_pts, 2 * self.min_pts * max(bp, 1))
        return min(max(self.capacity // 4, 32), want)

    def _eff_s_cap(self, bp: int) -> int:
        # S' (survivor nodes outside the largest survivor component)
        # does NOT shrink with the block: one cut inter-cluster bridge
        # strands a whole cluster regardless of how few points were
        # deleted.  A flat capacity/4 bucket keeps the per-block cost
        # predictable and makes overflow mean "more than a quarter of
        # the population stranded" — genuinely rebuild territory.
        return max(64, self.capacity // 4)

    def _grow_to(self, cap: int):
        old = self.capacity
        cap = 1 << (max(cap - 1, 1)).bit_length()
        if cap <= old:
            return
        s = self.state
        pad = cap - old
        self.state = DynState(
            X=jnp.pad(s.X, ((0, pad), (0, 0))),
            alive=jnp.pad(s.alive, (0, pad)),
            knn_idx=jnp.pad(s.knn_idx, ((0, pad), (0, 0)), constant_values=-1),
            knn_dst=jnp.pad(s.knn_dst, ((0, pad), (0, 0)), constant_values=jnp.inf),
            cd=jnp.pad(s.cd, (0, pad)),
            mst_u=jnp.pad(s.mst_u, (0, pad)),
            mst_v=jnp.pad(s.mst_v, (0, pad)),
            mst_raw=jnp.pad(s.mst_raw, (0, pad)),
            mst_valid=jnp.pad(s.mst_valid, (0, pad)),
            n_alive=s.n_alive,
            ok=s.ok,
        )
        self._free.extend(range(cap - 1, old - 1, -1))
        self.stats["grows"] += 1

    def _pad_block(self, arrs, n: int):
        bp = max(self.MIN_BLOCK, 1 << (max(n - 1, 1)).bit_length())
        out = []
        for a in arrs:
            pad = [(0, bp - n)] + [(0, 0)] * (a.ndim - 1)
            out.append(np.pad(a, pad))
        valid = np.arange(bp) < n
        return out, valid

    def would_grow(self, n_new: int) -> bool:
        return len(self._free) < int(n_new)

    # -- updates -----------------------------------------------------------

    def insert_block(self, X) -> list[int]:
        X = np.asarray(X, dtype=np.float32).reshape(-1, self.dim)
        B = X.shape[0]
        if B == 0:
            return []
        if self.would_grow(B):
            self._grow_to(self.capacity + B)
        slots = [self._free.pop() for _ in range(B)]
        (Xp, sp), valid = self._pad_block([X, np.asarray(slots, np.int64)], B)
        rk = self._rk_cap if self._rk_cap is not None else self._eff_cap(len(valid))
        self.state = insert_batch(
            self.state, jnp.asarray(Xp), jnp.asarray(sp), jnp.asarray(valid),
            min_pts=self.min_pts, rk_cap=rk,
        )
        self.stats["inserts"] += B
        if not self.ok:
            self.stats["overflow_rebuilds"] += 1
            self.rebuild()
        return slots

    def delete_block(self, slots):
        slots = [int(s) for s in slots]
        B = len(slots)
        if B == 0:
            return
        (sp,), valid = self._pad_block([np.asarray(slots, np.int64)], B)
        rk = self._rk_cap if self._rk_cap is not None else self._eff_cap(len(valid))
        sc = self._s_cap if self._s_cap is not None else self._eff_s_cap(len(valid))
        self.state = delete_batch(
            self.state, jnp.asarray(sp), jnp.asarray(valid),
            min_pts=self.min_pts, rk_cap=rk, s_cap=sc,
        )
        self._free.extend(reversed(slots))
        self.stats["deletes"] += B
        if not self.ok:
            # an RkNN/S' strip overflowed its bucket: the exact regime the
            # paper's feasibility study calls uneconomical — rebuild
            self.stats["overflow_rebuilds"] += 1
            self.rebuild()

    def rebuild(self):
        """From-scratch device pass over the current X/alive (the hybrid
        path's full-pass fallback)."""
        self.state = rebuild(self.state, min_pts=self.min_pts)

    def load(self, X, slots=None, shrink: bool = False):
        """Replace the population: X rows land in ``slots`` (default
        0..n-1) and everything is rebuilt from scratch.  ``shrink``
        re-buckets capacity to ~1.5× the population first — the engine's
        full-pass fallback uses it so a rebuild never pays for a stale
        oversized bucket."""
        X = np.asarray(X, dtype=np.float32).reshape(-1, self.dim)
        n = X.shape[0]
        slots = list(range(n)) if slots is None else [int(s) for s in slots]
        if len(slots) != n:
            raise ValueError(f"{n} rows but {len(slots)} slots")
        need = (max(slots) + 1) if slots else 1
        if shrink:
            tgt = max(16, 2 * self.min_pts, need, int(1.5 * n))
            tgt = 1 << (max(tgt - 1, 1)).bit_length()
            if tgt != self.capacity:
                self.state = init_state(tgt, self.dim, self.min_pts)
        if need > self.capacity:
            self._grow_to(need)
        cap = self.capacity
        Xb = np.zeros((cap, self.dim), np.float32)
        alive = np.zeros((cap,), bool)
        Xb[slots] = X
        alive[slots] = True
        self.state = self.state._replace(X=jnp.asarray(Xb), alive=jnp.asarray(alive))
        taken = set(slots)
        self._free = [i for i in range(cap - 1, -1, -1) if i not in taken]
        self.rebuild()
        return slots

    # -- inspection (host sync) --------------------------------------------

    def alive_slots(self) -> np.ndarray:
        return np.nonzero(np.asarray(self.state.alive))[0]

    def mst_edges(self):
        """(u, v, w_mutual) host arrays of the maintained tree."""
        valid = np.asarray(self.state.mst_valid)
        w = np.asarray(state_mst_weights(self.state), dtype=np.float64)
        return (
            np.asarray(self.state.mst_u, dtype=np.int64)[valid],
            np.asarray(self.state.mst_v, dtype=np.int64)[valid],
            w[valid],
        )

    def total_weight(self) -> float:
        return float(np.sum(np.asarray(state_mst_weights(self.state), np.float64)))
