"""Clustering features (CF) — Definition 4 of the paper.

A CF summarizes a point set P by the tuple ``{LS, SS, n}`` where

  * ``LS = sum(p for p in P)``            (vector linear sum, shape (d,))
  * ``SS = sum(||p||^2 for p in P)``      (scalar squared sum)
  * ``n  = |P|``                          (weight)

The *additivity theorem* (Eq. 2) makes CFs mergeable: CF_i + CF_j is the CF
of the union of the underlying point sets.  All operations here are written
against numpy arrays of CFs (structure-of-arrays) so a table of L CFs is

  LS: (L, d) float64     SS: (L,) float64     n: (L,) float64

which is exactly the layout the TPU offline pass (kernels/bubble_dist.py)
consumes without copies.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "CFTable",
    "cf_of_points",
    "cf_merge",
    "cf_add_point",
    "cf_remove_point",
    "cf_rep",
    "cf_extent",
    "cf_nn_dist",
]


@dataclasses.dataclass
class CFTable:
    """A dense table of L clustering features over R^d."""

    LS: np.ndarray  # (L, d)
    SS: np.ndarray  # (L,)
    n: np.ndarray  # (L,)

    @property
    def size(self) -> int:
        return int(self.LS.shape[0])

    @property
    def dim(self) -> int:
        return int(self.LS.shape[1])

    @staticmethod
    def empty(capacity: int, dim: int) -> "CFTable":
        return CFTable(
            LS=np.zeros((capacity, dim), dtype=np.float64),
            SS=np.zeros((capacity,), dtype=np.float64),
            n=np.zeros((capacity,), dtype=np.float64),
        )

    def rep(self) -> np.ndarray:
        """Representative points (Eq. 3), rows with n == 0 map to 0."""
        return cf_rep(self.LS, self.n)

    def extent(self) -> np.ndarray:
        """Extents (Eq. 4)."""
        return cf_extent(self.LS, self.SS, self.n)


def cf_of_points(X: np.ndarray, weights: np.ndarray | None = None):
    """CF of a point block ``X`` (m, d) -> (LS (d,), SS scalar, n scalar)."""
    X = np.asarray(X, dtype=np.float64)
    if weights is None:
        LS = X.sum(axis=0)
        SS = float(np.einsum("md,md->", X, X))
        n = float(X.shape[0])
    else:
        w = np.asarray(weights, dtype=np.float64)
        LS = (w[:, None] * X).sum(axis=0)
        SS = float(np.einsum("m,md,md->", w, X, X))
        n = float(w.sum())
    return LS, SS, n


def cf_merge(LS_i, SS_i, n_i, LS_j, SS_j, n_j):
    """Additivity theorem (Eq. 2): CF_i + CF_j."""
    return LS_i + LS_j, SS_i + SS_j, n_i + n_j


def cf_add_point(LS, SS, n, p):
    p = np.asarray(p, dtype=np.float64)
    return LS + p, SS + float(p @ p), n + 1.0


def cf_remove_point(LS, SS, n, p):
    """Inverse of :func:`cf_add_point` — CFs support exact removal because
    the statistics are sums (this is what makes *fully dynamic* maintenance
    possible, unlike e.g. max-based sketches)."""
    p = np.asarray(p, dtype=np.float64)
    return LS - p, SS - float(p @ p), n - 1.0


def cf_rep(LS: np.ndarray, n: np.ndarray) -> np.ndarray:
    """rep = LS / n (Eq. 3), vectorized over a CF table; 0 where n == 0."""
    n = np.asarray(n, dtype=np.float64)
    safe = np.maximum(n, 1.0)
    out = LS / safe[..., None]
    out[n == 0] = 0.0
    return out


def cf_extent(LS: np.ndarray, SS: np.ndarray, n: np.ndarray) -> np.ndarray:
    """extent = sqrt((2 n SS - 2 ||LS||^2) / (n (n - 1)))  (Eq. 4).

    This is sqrt(2) times the standard deviation radius: the average
    pairwise squared distance inside the bubble is
    2 (n*SS - ||LS||^2) / (n (n-1)).  CFs with n <= 1 have extent 0.
    Numerical noise can drive the radicand slightly negative; clamp.
    """
    LS = np.asarray(LS, dtype=np.float64)
    SS = np.asarray(SS, dtype=np.float64)
    n = np.asarray(n, dtype=np.float64)
    lsq = np.einsum("...d,...d->...", LS, LS)
    denom = np.maximum(n * (n - 1.0), 1.0)
    rad = (2.0 * n * SS - 2.0 * lsq) / denom
    rad = np.maximum(rad, 0.0)
    out = np.sqrt(rad)
    out = np.where(n <= 1.0, 0.0, out)
    return out


def cf_nn_dist(extent: np.ndarray, n: np.ndarray, k, dim: int) -> np.ndarray:
    """nnDist(k) = (k / n)^(1/d) * extent (Eq. 5).

    Estimates the distance from a bubble's representative to its k-th
    nearest member assuming points are uniformly distributed inside the
    extent radius.  ``k`` may be scalar or an array broadcastable with n.
    """
    n = np.maximum(np.asarray(n, dtype=np.float64), 1.0)
    k = np.minimum(np.asarray(k, dtype=np.float64), n)
    k = np.maximum(k, 0.0)
    return np.power(k / n, 1.0 / float(dim)) * extent
