"""Device-resident HDBSCAN hierarchy: single-linkage → condense → extract.

`core.hdbscan` keeps the sequential host implementation as the *oracle*;
this module is the jit-compatible array reformulation that lets the whole
offline pass (d_m → MST → dendrogram → condensed tree → flat labels) run
as ONE compiled call with no host round-trip (ISSUE 2 / ROADMAP "make a
hot path measurably faster").  Everything operates on fixed,
power-of-two-bucketed shapes so the streaming engine recompiles per
bucket, not per leaf count.

Padding scheme (shared with kernels.ops.offline_recluster):

  * ``Lp`` leaves, of which the first ``n_valid`` are real; pad leaves
    carry weight 0.
  * Borůvka returns (Lp,) edge buffers with ``n_valid - 1`` valid edges
    (pad rows are +inf-isolated and never connect).  The dendrogram
    needs ``Lp - 1`` merges, so the ``Lp - n_valid`` missing edges are
    synthesized: pad leaf ``n_valid + j`` is attached to node 0 at
    ``PAD_DIST`` (≫ any real d_m).  Sorted ascending, those merges land
    at the very top of the tree, where λ = 1/PAD_DIST ≈ 0 and weight 0
    — the condensed tree sees them as zero-mass members of the root
    cluster at λ→0, which perturbs neither stabilities nor labels.
  * One edge slot is always left over (``Lp`` slots, ``Lp - 1`` merges);
    it is parked at +inf and never processed.

Cluster labels are dense ints: 0 is the root cluster, children get
increasing labels in top-down processing order (so a child's label is
always greater than its parent's — both extraction loops rely on it).
They are a *relabeling* of the oracle's ``n, n+1, …`` convention; parity
tests compare up to permutation.

Sequential-but-on-device is the point: union-find single-linkage, the
condense DFS, and bottom-up EOM are O(Lp) `lax.scan`s (unroll=2 — the
measured CPU sweet spot between while-loop dispatch overhead and compile
time), while selection blocking and label resolution collapse to
O(log Lp) pointer-doubling sweeps.  All of it is tiny next to the
O(Lp²) d_m/Borůvka stages it fuses with, and it eliminates the per-pass
host sync + interpreted Python of the old path.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PAD_DIST",
    "MAX_LAMBDA",
    "SingleLinkageArrays",
    "CondensedArrays",
    "ExtractionArrays",
    "single_linkage_fixed",
    "condense_fixed",
    "extract_fixed",
    "hierarchy_fixed",
    "single_linkage_jax",
]

# Weight of the synthesized pad-leaf merges.  Far above any real mutual
# reachability but finite in f32 (so 1/PAD_DIST is a clean denormal-free
# ~1e-30, not a NaN-generating inf).
PAD_DIST = 1e30
# λ = 1/dist clamp for zero/denormal distances (duplicate points).  The
# host oracle uses np.inf and clamps at 1e308 inside the stability sum;
# 1e12 keeps (λ · total_weight) comfortably inside f32.
MAX_LAMBDA = 1e12


class SingleLinkageArrays(NamedTuple):
    """scipy-``linkage``-style merge records over 2·Lp−1 node ids.

    Row k merges ``left[k]``/``right[k]`` (node ids; leaves < Lp,
    internal node ``Lp + k``) at ``dist[k]`` into weight ``weight[k]``.
    Skipped slots (disconnected inputs — never the MST path) point both
    children at the trash node ``2·Lp − 1``.
    """

    left: jax.Array  # (Lp-1,) int32
    right: jax.Array  # (Lp-1,) int32
    dist: jax.Array  # (Lp-1,) f32
    weight: jax.Array  # (Lp-1,) f32
    node_weight: jax.Array  # (2*Lp,) f32 — per-node subtree weight (+ trash)


class CondensedArrays(NamedTuple):
    """Array-form condensed tree (oracle: hdbscan.CondensedTree).

    Point rows: leaf i belongs to condensed cluster ``point_parent[i]``
    from λ ``point_lambda[i]``.  Cluster rows: label c ≥ 1 is a child of
    ``cluster_parent[c]`` born at ``cluster_birth[c]`` carrying
    ``cluster_weight[c]``; label 0 is the root (birth 0).  Slots ≥
    ``n_labels`` are unused (parent = trash index).
    """

    point_parent: jax.Array  # (Lp,) int32 — condensed cluster label per leaf
    point_lambda: jax.Array  # (Lp,) f32
    point_weight: jax.Array  # (Lp,) f32 — leaf weights (pads 0)
    cluster_parent: jax.Array  # (C+1,) int32, C = 2*Lp
    cluster_birth: jax.Array  # (C+1,) f32
    cluster_weight: jax.Array  # (C+1,) f32
    n_labels: jax.Array  # () int32 — labels in use (root included)


class ExtractionArrays(NamedTuple):
    stability: jax.Array  # (C+1,) f32 — per condensed cluster label
    selected: jax.Array  # (C+1,) bool — flat-extraction winners
    labels: jax.Array  # (Lp,) int32 — per-leaf flat labels, -1 noise
    n_clusters: jax.Array  # () int32


# --------------------------------------------------------------------------
# step 4: single-linkage dendrogram from fixed-size MST buffers
# --------------------------------------------------------------------------

def single_linkage_fixed(eu, ev, ew, valid, n_valid, weights) -> SingleLinkageArrays:
    """Edge-sorted union-find single-linkage over padded edge buffers.

    Args:
      eu, ev, ew, valid: (Lp,) Borůvka edge buffers (``kernels.ops`` /
        ``mst.boruvka_jax`` layout); exactly ``n_valid - 1`` valid edges
        for a connected valid block.
      n_valid: () int — real leaf count L; leaves ≥ L are padding.
      weights: (Lp,) f32 leaf weights (pad rows 0).

    Union-find is component *relabeling* (O(Lp) vectorized `where` per
    merge) rather than pointer chasing: each merge relabels the absorbed
    component in one VPU sweep, so there are no data-dependent find
    depths and the loop body is branch-free.
    """
    Lp = eu.shape[0]
    M = Lp - 1
    trash_node = 2 * Lp - 1

    eu = eu.astype(jnp.int32)
    ev = ev.astype(jnp.int32)
    ew = ew.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    n_valid = jnp.asarray(n_valid, jnp.int32)

    # synthesize the pad merges: j-th invalid slot attaches pad leaf
    # n_valid + j to node 0 at PAD_DIST; surplus slots park at +inf
    inv_rank = jnp.cumsum((~valid).astype(jnp.int32)) - 1
    pad_leaf = n_valid + inv_rank
    is_pad = (~valid) & (pad_leaf < Lp)
    u_e = jnp.where(valid, eu, jnp.where(is_pad, pad_leaf, 0))
    v_e = jnp.where(valid, ev, 0)
    pad_w = jnp.where(
        is_pad, jnp.asarray(PAD_DIST, ew.dtype), jnp.asarray(jnp.inf, ew.dtype)
    )
    w_e = jnp.where(valid, ew, pad_w)

    order = jnp.argsort(w_e, stable=True)
    u_s, v_s, w_s = u_e[order], v_e[order], w_e[order]

    comp0 = jnp.arange(Lp, dtype=jnp.int32)
    node_of_comp0 = jnp.concatenate(
        [comp0, jnp.asarray([trash_node], jnp.int32)]
    )  # (Lp+1,): slot Lp absorbs skipped-merge writes
    node_weight0 = jnp.zeros((2 * Lp,), jnp.float32).at[:Lp].set(weights)
    zeros_m = jnp.zeros((M + 1,), jnp.float32)
    trash_i32 = jnp.full((M + 1,), trash_node, jnp.int32)

    def body(k, state):
        comp, node_of_comp, node_weight, ml, mr, md, mw = state
        u, v, w = u_s[k], v_s[k], w_s[k]
        ca, cb = comp[u], comp[v]
        ok = ca != cb  # surplus +inf slots / disconnected inputs: no-op
        na, nb = node_of_comp[ca], node_of_comp[cb]
        wsum = node_weight[na] + node_weight[nb]
        slot = jnp.where(ok, k, M)  # rejected merges land in the trash row
        ml = ml.at[slot].set(jnp.where(ok, na, trash_node))
        mr = mr.at[slot].set(jnp.where(ok, nb, trash_node))
        md = md.at[slot].set(w)
        mw = mw.at[slot].set(wsum)
        node_weight = node_weight.at[jnp.where(ok, Lp + k, trash_node)].set(wsum)
        comp = jnp.where(comp == cb, ca, comp)
        node_of_comp = node_of_comp.at[jnp.where(ok, ca, Lp)].set(Lp + k)
        return comp, node_of_comp, node_weight, ml, mr, md, mw

    state = (
        comp0,
        node_of_comp0,
        node_weight0,
        trash_i32.copy(),
        trash_i32.copy(),
        zeros_m.copy(),
        zeros_m.copy(),
    )
    # scan+unroll over fori_loop: amortizes the per-iteration while-loop
    # dispatch that dominates these O(1)-body loops on CPU
    state, _ = jax.lax.scan(
        lambda s, k: (body(k, s), None), state, jnp.arange(M, dtype=jnp.int32), unroll=2
    )
    _, _, node_weight, ml, mr, md, mw = state
    return SingleLinkageArrays(ml[:M], mr[:M], md[:M], mw[:M], node_weight)


# --------------------------------------------------------------------------
# step 5a: condensed tree (array-form DFS, top-down over node ids)
# --------------------------------------------------------------------------

def condense_fixed(slt: SingleLinkageArrays, weights, min_cluster_size) -> CondensedArrays:
    """Collapse the dendrogram exactly like ``hdbscan.condense_tree``:

    a split spawns two new condensed clusters only when both sides are
    structural subtrees carrying ≥ min_cluster_size weight; one heavy
    side continues its parent's label; light sides "fall out" leaf by
    leaf at the split's λ.  Node ids descend from the root (internal ids
    increase with merge order), so one top-down fori_loop settles every
    node's (condensed label, entry λ, fallen?) before it is visited.
    """
    M = slt.left.shape[0]
    Lp = M + 1
    n_nodes = 2 * Lp - 1  # + slot n_nodes = trash
    C = 2 * Lp  # max condensed cluster labels (1 root + 2 per split)
    trash_label = C
    mcs = jnp.asarray(min_cluster_size, jnp.float32)
    weights = weights.astype(jnp.float32)

    root = n_nodes - 1
    lam_of = jnp.where(
        slt.dist > 0.0, jnp.minimum(1.0 / slt.dist, MAX_LAMBDA), MAX_LAMBDA
    ).astype(jnp.float32)

    cl0 = jnp.zeros((n_nodes + 1,), jnp.int32)  # root enters cluster 0
    lam0 = jnp.zeros((n_nodes + 1,), jnp.float32)
    fal0 = jnp.zeros((n_nodes + 1,), bool)
    cp0 = jnp.full((C + 1,), trash_label, jnp.int32)
    cb0 = jnp.zeros((C + 1,), jnp.float32)
    cw0 = jnp.zeros((C + 1,), jnp.float32).at[0].set(slt.node_weight[root])

    def body(t, state):
        cl, lam_in, fallen, cp, cb, cw, nxt = state
        i = M - 1 - t  # merge index; node id Lp + i, root first
        node = Lp + i
        P, lin, fal = cl[node], lam_in[node], fallen[node]
        lc, rc = slt.left[i], slt.right[i]
        lam = lam_of[i]
        wl, wr = slt.node_weight[lc], slt.node_weight[rc]
        l_c = (wl >= mcs) & (lc >= Lp)  # heavy AND structural (internal)
        r_c = (wr >= mcs) & (rc >= Lp)
        both = l_c & r_c & ~fal
        A, B = nxt, nxt + 1
        cl = cl.at[lc].set(jnp.where(both, A, P)).at[rc].set(jnp.where(both, B, P))
        child_lam = jnp.where(fal, lin, lam)
        lam_in = lam_in.at[lc].set(child_lam).at[rc].set(child_lam)
        # a child stays "live" only if it founds a cluster (both) or is
        # the single continuing heavy side; everything else falls out
        fallen = (
            fallen.at[lc].set(fal | ~(both | (l_c & ~r_c)))
            .at[rc].set(fal | ~(both | (r_c & ~l_c)))
        )
        sa = jnp.where(both, A, trash_label)
        sb = jnp.where(both, B, trash_label)
        cp = cp.at[sa].set(P).at[sb].set(P)
        cb = cb.at[sa].set(lam).at[sb].set(lam)
        cw = cw.at[sa].set(wl).at[sb].set(wr)
        return cl, lam_in, fallen, cp, cb, cw, nxt + 2 * both.astype(jnp.int32)

    state = (cl0, lam0, fal0, cp0, cb0, cw0, jnp.asarray(1, jnp.int32))
    state, _ = jax.lax.scan(
        lambda s, t: (body(t, s), None), state, jnp.arange(M), unroll=2
    )
    cl, lam_in, _, cp, cb, cw, n_labels = state
    # trash-label writes must not corrupt slot C's defaults for readers
    cp = cp.at[trash_label].set(trash_label)
    cb = cb.at[trash_label].set(0.0)
    cw = cw.at[trash_label].set(0.0)
    return CondensedArrays(
        point_parent=cl[:Lp],
        point_lambda=lam_in[:Lp],
        point_weight=weights,
        cluster_parent=cp,
        cluster_birth=cb,
        cluster_weight=cw,
        n_labels=n_labels,
    )


# --------------------------------------------------------------------------
# step 5b: stabilities + flat extraction + label resolution
# --------------------------------------------------------------------------

def extract_fixed(
    ct: CondensedArrays,
    method: str = "eom",
    allow_single_cluster: bool = False,
) -> ExtractionArrays:
    """Excess-of-mass (or leaf) extraction over the array condensed tree.

    stability(c) = Σ_rows (λ_row − λ_birth(c)) · w_row, via two scatter
    adds.  EOM runs as one descending fori_loop: child labels exceed
    their parent's, so each cluster's children are final when visited;
    a running scatter into the parent's accumulator replaces the
    subtree-stability dict of the oracle.  Selection blocking and label
    resolution are one ascending loop each (parents final first).
    """
    C = ct.cluster_parent.shape[0] - 1
    trash = C
    ids = jnp.arange(C + 1, dtype=jnp.int32)
    in_use = ids < ct.n_labels

    # --- stabilities (root birth is 0 by construction) ---
    birth = ct.cluster_birth
    stab = jnp.zeros((C + 1,), jnp.float32)
    stab = stab.at[ct.point_parent].add(
        (ct.point_lambda - birth[ct.point_parent]) * ct.point_weight
    )
    row_mask = in_use & (ids >= 1)
    par_of = jnp.where(row_mask, ct.cluster_parent, trash)
    stab = stab.at[par_of].add(
        jnp.where(row_mask, (birth - birth[par_of]) * ct.cluster_weight, 0.0)
    )

    # --- bottom-up EOM: selected iff stability ≥ Σ selected-descendant ---
    # (the only stage that stays a sequential sweep: the subtree sum
    # flips through the selection flag, so no pointer-doubling shortcut)
    def eom_body(state, t):
        acc, kids, sel = state
        c = C - 1 - t
        live = c < ct.n_labels
        s, ksum = stab[c], acc[c]
        is_sel = live & ((kids[c] == 0) | (s >= ksum))
        sub = jnp.where(is_sel, s, ksum)
        sel = sel.at[c].set(is_sel)
        p = jnp.where(live & (c >= 1), ct.cluster_parent[c], trash)
        return (acc.at[p].add(sub), kids.at[p].add(1), sel), None

    acc0 = jnp.zeros((C + 1,), jnp.float32)
    kids0 = jnp.zeros((C + 1,), jnp.int32)
    sel0 = jnp.zeros((C + 1,), bool)
    (_, kid_count, sel), _ = jax.lax.scan(
        eom_body, (acc0, kids0, sel0), jnp.arange(C), unroll=2
    )

    # pointer-doubling setup: the label tree is ≤ C deep but log₂(C)
    # doubling steps traverse any ancestor chain
    n_jumps = max(C - 1, 1).bit_length() + 1  # ceil(log2(max(C, 2))) + 1
    parent_or_trash = jnp.where(in_use & (ids >= 1), ct.cluster_parent, trash)

    if method == "leaf":
        eff = in_use & (kid_count == 0) & (allow_single_cluster | (ids != 0))
    else:
        # a selected cluster blocks every selected descendant; the root
        # only counts when allow_single_cluster.  "blocked" ⇔ some proper
        # ancestor is selected-and-allowed — an OR over the ancestor
        # chain, computed by pointer doubling in log₂(C) vector steps
        sel_allowed = sel & (allow_single_cluster | (ids != 0)) & in_use

        def or_step(state, _):
            g, anc = state
            return (g[g], anc | anc[g]), None

        (_, anc_or), _ = jax.lax.scan(
            or_step, (parent_or_trash, sel_allowed[parent_or_trash]),
            None, length=n_jumps,
        )
        eff = sel_allowed & ~anc_or
    if allow_single_cluster:
        none = ~eff.any()
        eff = eff.at[0].set(eff[0] | none)
    eff = eff & in_use

    # --- labels: nearest selected ancestor-or-self, ranked ascending ---
    # f[c] = c where selected else parent; doubling converges every label
    # onto its nearest selected ancestor (or trash ⇒ noise)
    rank = (jnp.cumsum(eff.astype(jnp.int32)) - 1).astype(jnp.int32)
    f0 = jnp.where(eff, ids, parent_or_trash)

    def hop(f, _):
        return jnp.where(eff[f], f, f[f]), None

    f, _ = jax.lax.scan(hop, f0, None, length=n_jumps)
    resolved = jnp.where(eff[f], rank[f], -1)
    labels = resolved[ct.point_parent]
    return ExtractionArrays(
        stability=stab, selected=eff, labels=labels, n_clusters=eff.sum().astype(jnp.int32)
    )


# trace-contract: hierarchy_fixed rules=f32,no-callbacks,pow2
@functools.partial(jax.jit, static_argnames=("method", "allow_single_cluster"))
def hierarchy_fixed(
    eu, ev, ew, valid, n_valid, weights, min_cluster_size,
    method: str = "eom",
    allow_single_cluster: bool = False,
):
    """MST buffers → (SingleLinkageArrays, CondensedArrays, ExtractionArrays).

    The fully fused device path, shape-static in Lp.  jit'd here so eager
    callers (tests, notebooks) hit the per-bucket compile cache instead
    of re-tracing the scans each call; inside `kernels.ops`'s fused
    pipeline the jit nests and inlines.
    """
    slt = single_linkage_fixed(eu, ev, ew, valid, n_valid, weights)
    ct = condense_fixed(slt, jnp.asarray(weights, jnp.float32), min_cluster_size)
    ex = extract_fixed(ct, method=method, allow_single_cluster=allow_single_cluster)
    return slt, ct, ex


# --------------------------------------------------------------------------
# explicit-edge-list convenience (property tests / oracle comparisons)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(4,))
def _sl_fixed_jit(eu, ev, ew, valid, n, weights):
    return single_linkage_fixed(eu, ev, ew, valid, jnp.asarray(n, jnp.int32), weights)


def single_linkage_jax(u, v, w, n: int, weights=None):
    """Device single-linkage from an explicit edge list (host mirror of
    ``hdbscan.single_linkage``).  Pads to the power-of-two bucket, runs
    the fixed kernel, and returns the ``n - 1`` real merge records as
    host numpy ``(left, right, dist, weight)`` — pad merges (attached at
    PAD_DIST) are sliced away, exactly the rows the oracle produces.
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    if weights is None:
        weights = np.ones(n, dtype=np.float64)
    Lp = max(8, 1 << (max(n - 1, 1)).bit_length())
    E = u.shape[0]
    if E != n - 1:
        # the fixed kernel assumes a spanning tree (MST output); fewer
        # edges would leave unwritten trash rows in the result and more
        # would drop the heaviest ones — reject rather than corrupt
        raise ValueError(f"expected a spanning tree ({n - 1} edges for n={n}), got {E}")
    eu = np.zeros(Lp, dtype=np.int32)
    ev = np.zeros(Lp, dtype=np.int32)
    ew = np.zeros(Lp, dtype=np.float32)
    valid = np.zeros(Lp, dtype=bool)
    eu[:E], ev[:E], ew[:E], valid[:E] = u, v, w, True
    wpad = np.zeros(Lp, dtype=np.float32)
    wpad[:n] = weights
    slt = _sl_fixed_jit(
        jnp.asarray(eu), jnp.asarray(ev), jnp.asarray(ew), jnp.asarray(valid),
        int(n), jnp.asarray(wpad),
    )
    keep = np.asarray(slt.dist) < PAD_DIST
    # real merges are the first n-1 in sorted order (pads sort above
    # them), so their internal ids Lp+k remap to the oracle's n+k
    left = np.asarray(slt.left)[keep]
    right = np.asarray(slt.right)[keep]
    left = np.where(left >= Lp, left - Lp + n, left)
    right = np.where(right >= Lp, right - Lp + n, right)
    return (
        left,
        right,
        np.asarray(slt.dist, dtype=np.float64)[keep],
        np.asarray(slt.weight, dtype=np.float64)[keep],
    )
