# Dev entry points.  PYTHONPATH is injected so targets work from a clean
# checkout; see README.md for what each target covers.

PYTHON ?= python
PYTEST_FLAGS ?= -x -q
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench-smoke docs-links check

test:
	$(PYTHON) -m pytest $(PYTEST_FLAGS)

bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig8

docs-links:
	$(PYTHON) scripts/check_docs_links.py

check: docs-links test
