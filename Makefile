# Dev entry points.  PYTHONPATH is injected so targets work from a clean
# checkout; see README.md for what each target covers.

PYTHON ?= python
PYTEST_FLAGS ?= -x -q
export PYTHONPATH := src:$(PYTHONPATH)

# ruff-format adoption list: files here are kept black-clean; the
# pre-existing tree is linted (ruff check) but not reflowed wholesale.
FORMAT_PATHS ?= scripts/check_bench_regression.py tools/lint tools/audit \
  src/repro/serving/tenants.py src/repro/core/device_table.py

.PHONY: test test-multidevice bench-smoke bench-gate docs-links lint \
  lint-deep audit check

test:
	$(PYTHON) -m pytest $(PYTEST_FLAGS)

# Simulated multi-device leg (DESIGN.md §12): the sharding / streaming /
# parity suites with 8 host devices forced, so shard_map really runs
# 8-way.  Plain `make test` keeps the single real CPU device on purpose
# (tests/conftest.py) — this target is the only one that overrides it.
test-multidevice:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  $(PYTHON) -m pytest $(PYTEST_FLAGS) tests/test_mesh_sharding.py \
	  tests/test_sharding.py tests/test_streaming.py tests/test_bubble_flat.py \
	  tests/test_grid_pruning.py

bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig8,fig3_dynamic,fig5_query,fig7_pruned,fig7_mesh,fig9

# CI perf gate: fresh smoke run (bench_out/ by default), compared against
# the checked-in bench_results/ baselines (1.5x default; REPRO_BENCH_TOL=…).
# Refresh baselines deliberately: REPRO_BENCH_DIR=bench_results make bench-smoke
bench-gate: bench-smoke
	$(PYTHON) scripts/check_bench_regression.py --fresh bench_out

docs-links:
	$(PYTHON) scripts/check_docs_links.py

lint:
	ruff check .
	ruff format --check $(FORMAT_PATHS)

# repro-lint (tools/lint): AST-level contract checks — jit purity (RPL1xx),
# dtype discipline (RPL2xx), serve-plane lock discipline (RPL3xx), kernel
# hygiene (RPL4xx).  Exit 1 = new findings, exit 2 = baseline drift.
lint-deep:
	$(PYTHON) -m tools.lint src tests benchmarks scripts

# jaxpr-audit (tools/audit): abstract-trace contract analysis over every
# registered jit entry point (DESIGN.md §14) — f64/callback/pow-2/dense
# rules (RPL50x), recompile-churn gate, golden lowering digests.
# Regenerate goldens deliberately: python -m tools.audit --update-golden
audit:
	$(PYTHON) -m tools.audit

check: docs-links lint lint-deep audit test
