# Dev entry points.  PYTHONPATH is injected so targets work from a clean
# checkout; see README.md for what each target covers.

PYTHON ?= python
PYTEST_FLAGS ?= -x -q
export PYTHONPATH := src:$(PYTHONPATH)

# ruff-format adoption list: files here are kept black-clean; the
# pre-existing tree is linted (ruff check) but not reflowed wholesale.
FORMAT_PATHS ?= scripts/check_bench_regression.py

.PHONY: test bench-smoke bench-gate docs-links lint check

test:
	$(PYTHON) -m pytest $(PYTEST_FLAGS)

bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig8,fig3_dynamic,fig5_query,fig7_pruned,fig9

# CI perf gate: fresh smoke run (bench_out/ by default), compared against
# the checked-in bench_results/ baselines (1.5x default; REPRO_BENCH_TOL=…).
# Refresh baselines deliberately: REPRO_BENCH_DIR=bench_results make bench-smoke
bench-gate: bench-smoke
	$(PYTHON) scripts/check_bench_regression.py --fresh bench_out

docs-links:
	$(PYTHON) scripts/check_docs_links.py

lint:
	ruff check .
	ruff format --check $(FORMAT_PATHS)

check: docs-links lint test
